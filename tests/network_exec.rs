//! Whole-network execution engine tests: model-scope analytic-vs-sim
//! agreement, executor totals as the sum of independently simulated
//! layers, thread-count invariance, plan JSON round-trips, and the
//! headline property of the plan search — the `best_per_layer` plan's
//! total runtime never exceeds any uniform plan's total.

use noc_dnn::analytic;
use noc_dnn::config::{Collection, DataflowKind, SimConfig, Streaming};
use noc_dnn::coordinator::executor::{
    best_plan_search, NetworkExecutor, PlanSearchOptions,
};
use noc_dnn::dataflow::run_layer;
use noc_dnn::models::Network;
use noc_dnn::plan::{policy_grid, reload_cycles, LayerPolicy, NetworkPlan};

#[test]
fn model_scope_analytic_matches_sim_on_alexnet_uniform() {
    // The model-scope generalization of the per-layer Eq. (3)/(4)
    // cross-checks: summed closed forms + boundary reloads vs summed
    // extrapolated simulations + the same reloads, same tolerance class
    // (5%) as tests/analytic_vs_sim.rs.
    let cfg = SimConfig::table1_8x8(4);
    let model = Network::alexnet();
    let plan = NetworkPlan::uniform(LayerPolicy::proposed(), model.len());
    let sim = NetworkExecutor::new(cfg.clone()).run(&model, &plan).unwrap();
    let closed = analytic::network_latency(&cfg, &model, &plan);
    let err = (sim.total_cycles as f64 - closed as f64).abs() / closed as f64;
    assert!(
        err < 0.05,
        "model-scope sim {} vs closed form {closed} ({:.1}% off)",
        sim.total_cycles,
        err * 100.0
    );
}

#[test]
fn executor_totals_equal_sum_of_independently_simulated_layers() {
    // The executor adds nothing beyond the per-layer driver runs and the
    // closed-form boundary charges: rerunning each layer independently
    // through `dataflow::run_layer` under its policy's config reproduces
    // the executor's totals exactly (simulations are pure functions, so
    // "fixed seed" is the configuration itself).
    let mut cfg = SimConfig::table1_8x8(2);
    cfg.sim_rounds_cap = 3;
    let model = Network::alexnet();
    let mut plan = NetworkPlan::uniform(LayerPolicy::proposed(), model.len());
    plan.policies[1].collection = Collection::Ina;
    plan.policies[3].dataflow = DataflowKind::WeightStationary;
    let rep = NetworkExecutor::new(cfg.clone()).run(&model, &plan).unwrap();

    let mut expected_total = 0u64;
    for (i, layer) in model.layers.iter().enumerate() {
        let policy = plan.policy(i);
        let lcfg = policy.apply(&cfg);
        let run = run_layer(&lcfg, policy.streaming, policy.collection, layer);
        let reload = reload_cycles(&lcfg, policy.streaming, model.input_words(i));
        assert_eq!(
            rep.layers[i].report.run.total_cycles, run.total_cycles,
            "layer {i} diverged from its independent simulation"
        );
        assert_eq!(rep.layers[i].report.run.net, run.net, "layer {i} stats diverged");
        assert_eq!(rep.layers[i].reload_cycles, reload);
        expected_total += run.total_cycles + reload;
    }
    assert_eq!(rep.total_cycles, expected_total);
    let energy_sum: f64 = rep.layers.iter().map(|l| l.report.power.total_j).sum();
    assert!((rep.total_energy_j - energy_sum).abs() < 1e-12);
}

#[test]
fn executor_totals_are_invariant_across_thread_counts() {
    let model = Network::resnet_lite();
    let plan = NetworkPlan::uniform(LayerPolicy::proposed(), model.len());
    let run_with = |threads: usize| {
        let mut cfg = SimConfig::table1_8x8(2);
        cfg.sim_rounds_cap = 2;
        cfg.threads = threads;
        NetworkExecutor::new(cfg).run(&model, &plan).unwrap()
    };
    let serial = run_with(1);
    for threads in [0usize, 2, 4, 8] {
        let parallel = run_with(threads);
        assert_eq!(serial.total_cycles, parallel.total_cycles, "threads={threads}");
        assert_eq!(serial.total_energy_j, parallel.total_energy_j, "threads={threads}");
        for (a, b) in serial.layers.iter().zip(&parallel.layers) {
            assert_eq!(a.total_cycles, b.total_cycles);
            assert_eq!(a.report.run.net, b.report.run.net);
        }
    }
}

#[test]
fn network_plan_roundtrips_through_json() {
    let model = Network::vgg16();
    let mut plan = NetworkPlan::uniform(LayerPolicy::proposed(), model.len());
    plan.name = "mixed".to_string();
    plan.policies[0].streaming = Streaming::Mesh;
    plan.policies[1].collection = Collection::RepetitiveUnicast;
    plan.policies[2].collection = Collection::Ina;
    plan.policies[3].dataflow = DataflowKind::WeightStationary;
    let text = plan.to_json().to_pretty();
    let back = NetworkPlan::from_json(&text).unwrap();
    assert_eq!(back, plan);
    back.validate(&model).unwrap();
}

#[test]
fn model_report_json_has_per_layer_rows_and_totals() {
    // The `noc-dnn model --json` contract: a row per layer plus model
    // totals.
    let mut cfg = SimConfig::table1_8x8(2);
    cfg.sim_rounds_cap = 2;
    let model = Network::alexnet();
    let plan = NetworkPlan::uniform(LayerPolicy::proposed(), model.len());
    let rep = NetworkExecutor::new(cfg).run(&model, &plan).unwrap();
    let j = noc_dnn::coordinator::report::network_run_json(&rep);
    let layers = j.get("layers").unwrap().as_arr().unwrap();
    assert_eq!(layers.len(), model.len());
    assert_eq!(layers[0].get("layer").unwrap().as_str(), Some("conv1"));
    assert!(layers[0].get("policy").is_some());
    assert!(layers[0].get("total_cycles").unwrap().as_u64().unwrap() > 0);
    // Layer metadata rides along: MACs and output volume per row.
    assert_eq!(
        layers[0].get("macs").unwrap().as_u64(),
        Some(model.layers[0].total_macs())
    );
    assert_eq!(
        layers[0].get("out_words").unwrap().as_u64(),
        Some(model.layers[0].output_volume())
    );
    assert_eq!(
        j.get("total_cycles").unwrap().as_u64(),
        Some(rep.total_cycles)
    );
    assert!(j.get("total_energy_j").unwrap().as_f64().unwrap() > 0.0);
}

fn assert_best_beats_every_uniform(model: &Network) {
    let mut cfg = SimConfig::table1_8x8(2);
    cfg.sim_rounds_cap = 2; // keep the grid sweep cheap; extrapolation covers the rest
    // include_mesh + an infinite prune factor make every policy of the
    // 18-combo grid sim-verified per layer (nothing is analytically
    // pruned), so best ≤ every uniform holds by construction — the
    // evaluations are the same deterministic `evaluate_layer` calls the
    // executor redoes below.
    let opts = PlanSearchOptions { include_mesh: true, prune_factor: f64::INFINITY };
    let search = best_plan_search(&cfg, model, &opts);
    let ex = NetworkExecutor::new(cfg.clone());
    let best_total = ex.run(model, &search.plan).unwrap().total_cycles;
    for policy in policy_grid() {
        let uniform = NetworkPlan::uniform(policy, model.len());
        let total = ex.run(model, &uniform).unwrap().total_cycles;
        assert!(
            best_total <= total,
            "{}: best plan ({best_total}) lost to uniform {} ({total})",
            model.name,
            policy.label()
        );
    }
}

#[test]
fn best_plan_beats_every_uniform_on_alexnet() {
    assert_best_beats_every_uniform(&Network::alexnet());
}

#[test]
fn best_plan_beats_every_uniform_on_vgg16() {
    assert_best_beats_every_uniform(&Network::vgg16());
}

#[test]
fn resnet_lite_runs_under_every_collection() {
    // The stride-2 / 1x1 shapes flow through the whole engine.
    let mut cfg = SimConfig::table1_8x8(2);
    cfg.sim_rounds_cap = 2;
    let model = Network::resnet_lite();
    for collection in [Collection::Gather, Collection::RepetitiveUnicast, Collection::Ina] {
        let mut p = LayerPolicy::proposed();
        p.collection = collection;
        let plan = NetworkPlan::uniform(p, model.len());
        let rep = NetworkExecutor::new(cfg.clone()).run(&model, &plan).unwrap();
        assert_eq!(rep.layers.len(), model.len());
        assert!(rep.total_cycles > 0);
        assert!(rep.total_energy_j > 0.0);
    }
}
