//! Invariant pyramid for the per-link observability layer
//! (`SimConfig::probes`, `noc::probes`).
//!
//! Base — **conservation**: the per-link probe counters are a partition
//! of the aggregate `NetStats` the simulator already maintains, so at
//! *every* cycle boundary — mid-flight, immediately after an idle
//! fast-forward jump, and after drain —
//! `Σ links flits == NetStats::link_traversals` bit-exactly, per-VC
//! planes sum back to their link totals, stream + result classes
//! partition every link, and the utilization series accounts for every
//! traversal. Randomized over mesh/torus/cmesh × all three collection
//! schemes (honouring the `NOC_COLLECTION` CI matrix pin).
//!
//! Middle — **exactness**: on workloads with closed-form traffic
//! (repetitive unicast, capacity-limited gather) the probe totals equal
//! the analytic flit-hop forms of `analytic::row_collection_flit_hops`
//! minus the ejection hops that never cross a link.
//!
//! Tip — **attribution**: a synthetic single-row hotspot has a known
//! strictly-hottest link (the east-most link of the posted row — link
//! load is monotone non-decreasing eastward and strictly maximal on the
//! last link once ≥ 2 packets cross it); `ProbeReport::bottleneck` must
//! name that link, its stage, and survive the mesh → torus swap
//! unchanged (gather collection never takes wrap links).

use noc_dnn::analytic;
use noc_dnn::config::{Collection, DataflowKind, SimConfig, Streaming, TopologyKind};
use noc_dnn::dataflow::run_layer;
use noc_dnn::models::ConvLayer;
use noc_dnn::noc::network::Network;
use noc_dnn::noc::{BottleneckStage, Coord, Port, ProbeReport};
use noc_dnn::util::rng::{check_cases, Rng};

/// Random collection scheme, overridable by the `NOC_COLLECTION` env var
/// (the CI matrix runs the suite once per mode).
fn random_collection(rng: &mut Rng) -> Collection {
    match std::env::var("NOC_COLLECTION") {
        Ok(s) => Collection::parse(&s).expect("NOC_COLLECTION must be ru|gather|ina"),
        Err(_) => *rng.choose(&[
            Collection::Gather,
            Collection::RepetitiveUnicast,
            Collection::Ina,
        ]),
    }
}

/// Intra-layer worker count from the `NOC_INTRA_WORKERS` CI matrix axis
/// (default 1 = sequential kernel). The whole invariant pyramid must
/// hold bit-for-bit under the band-parallel kernel too.
fn intra_workers_from_env() -> usize {
    match std::env::var("NOC_INTRA_WORKERS") {
        Ok(s) => s.parse().expect("NOC_INTRA_WORKERS must be a worker count"),
        Err(_) => 1,
    }
}

/// Random-but-valid probe-on config over all three fabrics.
fn random_cfg(rng: &mut Rng) -> SimConfig {
    let mesh = *rng.choose(&[4usize, 5, 8, 11]);
    let n = *rng.choose(&[1usize, 2, 4, 8]);
    let mut cfg = SimConfig::table1(if mesh >= 8 { mesh } else { 8 }, n);
    cfg.mesh_cols = mesh;
    cfg.mesh_rows = mesh;
    cfg.topology = *rng.choose(&[
        TopologyKind::Mesh,
        TopologyKind::Torus,
        TopologyKind::CMesh,
    ]);
    cfg.delta = rng.range(0, 3 * cfg.delta);
    cfg.gather_packet_flits = rng.range(2, 20) as usize;
    cfg.sim_rounds_cap = 4;
    cfg.probes = true;
    cfg.intra_workers = intra_workers_from_env();
    cfg.validate().unwrap();
    cfg
}

/// Assert every internal-consistency invariant of one snapshot, and that
/// its totals partition the network's own aggregates.
fn assert_probe_invariants(net: &Network, where_: &str) {
    let p = net.probe_report().expect("probes were enabled");
    assert_eq!(
        p.total_flits, net.stats.link_traversals,
        "{where_}: per-link flit sums do not partition link_traversals"
    );
    assert_eq!(
        p.total_flits,
        p.links.iter().map(|l| l.flits).sum::<u64>(),
        "{where_}: total_flits is not the sum of its links"
    );
    assert_eq!(
        p.total_payloads,
        p.links.iter().map(|l| l.payloads).sum::<u64>(),
        "{where_}: total_payloads is not the sum of its links"
    );
    assert_eq!(
        p.total_blocked_cycles,
        p.links.iter().map(|l| l.blocked_total()).sum::<u64>(),
        "{where_}: total_blocked_cycles is not the sum of its links"
    );
    assert_eq!(
        p.series.iter().sum::<u64>(),
        p.total_flits,
        "{where_}: utilization series loses traversals"
    );
    for l in &p.links {
        assert_eq!(
            l.per_vc_flits.iter().sum::<u64>(),
            l.flits,
            "{where_}: VC planes of {} do not sum to the link total",
            l.label()
        );
        assert!(
            l.stream_flits <= l.flits,
            "{where_}: {} has more stream flits than flits",
            l.label()
        );
        assert_eq!(
            l.stream_flits + l.result_flits(),
            l.flits,
            "{where_}: stream/result classes of {} do not partition it",
            l.label()
        );
        assert!(
            l.peak_bucket_flits <= l.flits,
            "{where_}: {} peak bucket exceeds its lifetime total",
            l.label()
        );
        assert!(
            l.flits == 0 || l.peak_bucket_flits > 0,
            "{where_}: {} carried flits but recorded no peak",
            l.label()
        );
    }
    if p.total_flits > 0 {
        assert!(p.bottleneck().is_some(), "{where_}: traffic flowed but no bottleneck");
        assert!(p.max_utilization() > 0.0, "{where_}: utilization lost the traffic");
    } else {
        assert_eq!(p.bottleneck(), None, "{where_}: bottleneck out of thin air");
    }
}

#[test]
fn prop_link_sums_partition_netstats_across_fabrics() {
    check_cases(0x0B5E7E, 40, |rng, case| {
        let cfg = random_cfg(rng);
        let collection = random_collection(rng);
        let mut net = Network::new(&cfg, collection);
        let mut posted = 0u64;
        for r in 0..rng.range(1, 3) {
            for y in 0..cfg.mesh_rows {
                for x in 0..cfg.mesh_cols {
                    if rng.chance(0.7) {
                        let p = rng.range(1, cfg.pes_per_router as u64) as u32;
                        net.post_result(r * 50, Coord::new(x as u16, y as u16), p);
                        posted += p as u64;
                    }
                }
            }
        }
        // Sample the invariants at a handful of mid-flight cycle
        // boundaries (cheap aggregates every boundary, full snapshot per
        // horizon), then once more after the drain.
        let mut horizon = 0u64;
        for _ in 0..4 {
            horizon += rng.range(10, 700);
            net.run_until(|_| false, horizon);
            assert_probe_invariants(&net, &format!("case {case} @{} {collection:?}", net.cycle));
        }
        let ok = net.run_until_idle(2_000_000);
        assert!(ok, "case {case}: failed to drain ({collection:?} {:?})", cfg.topology);
        assert_eq!(net.payloads_delivered, posted, "case {case}: delivery shortfall");
        assert_probe_invariants(&net, &format!("case {case} drained {collection:?}"));
    });
}

#[test]
fn prop_probe_invariants_survive_fast_forward_jumps() {
    // Bursts separated by multi-thousand-cycle idle gaps force the
    // quiescent fast-forward (and calendar-window hops) between bursts;
    // the per-link partition must hold right across every jump — a
    // traversal recorded into the wrong bucket or double-counted by the
    // clock jump breaks series/total reconciliation here.
    check_cases(0xFA57_0B5, 20, |rng, case| {
        let cfg = random_cfg(rng);
        let collection = random_collection(rng);
        let mut net = Network::new(&cfg, collection);
        let mut posted = 0u64;
        let mut at = 0u64;
        for _ in 0..rng.range(2, 5) {
            at += rng.range(3_000, 40_000);
            for y in 0..cfg.mesh_rows {
                if rng.chance(0.6) {
                    let x = rng.below(cfg.mesh_cols as u64) as u16;
                    let p = rng.range(1, cfg.pes_per_router as u64) as u32;
                    net.post_result(at, Coord::new(x, y as u16), p);
                    posted += p as u64;
                }
            }
            // Run into (and past) this burst, then audit the snapshot.
            net.run_until(|_| false, at + rng.range(1, 2_000));
            assert_probe_invariants(&net, &format!("case {case} jump@{}", net.cycle));
        }
        if posted == 0 {
            net.post_result(at, Coord::new(0, 0), 1);
            posted = 1;
        }
        let ok = net.run_until_idle(at + 2_000_000);
        assert!(ok, "case {case}: failed to drain after jumps");
        assert_eq!(net.payloads_delivered, posted, "case {case}: shortfall after jumps");
        assert_probe_invariants(&net, &format!("case {case} drained after jumps"));
        // The series must span the whole jump-heavy schedule gap-free
        // and exactly: a fast-forward that skips whole buckets pads
        // explicit zeros, so `len × bucket_cycles` covers the final
        // cycle with no bucket past it.
        let p = net.probe_report().unwrap();
        assert_eq!(
            p.series.len() as u64,
            net.cycle.div_ceil(p.bucket_cycles),
            "case {case}: series length does not reconcile with the final \
             cycle (cycle {}, bucket {})",
            net.cycle,
            p.bucket_cycles
        );
    });
}

#[test]
fn prop_driver_probe_report_covers_the_measured_prefix() {
    // Driver level: for every streaming × collection × dataflow policy,
    // the surfaced ProbeReport reconciles with the *measured* (never the
    // extrapolated) NetStats — the same contract `measured_net` keeps.
    let layer = ConvLayer { name: "probe", c: 8, h_in: 10, r: 3, stride: 1, pad: 1, q: 24 };
    for dataflow in [DataflowKind::OutputStationary, DataflowKind::WeightStationary] {
        for streaming in [Streaming::TwoWay, Streaming::OneWay, Streaming::Mesh] {
            for collection in
                [Collection::RepetitiveUnicast, Collection::Gather, Collection::Ina]
            {
                let mut cfg = SimConfig::table1_8x8(4);
                cfg.dataflow = dataflow;
                cfg.sim_rounds_cap = 2;
                cfg.probes = true;
                let r = run_layer(&cfg, streaming, collection, &layer);
                let tag = format!("{dataflow:?}/{streaming:?}/{collection:?}");
                let p = r.probes.as_ref().unwrap_or_else(|| {
                    panic!("{tag}: probes on but no report surfaced")
                });
                assert_eq!(
                    p.total_flits, r.measured_net.link_traversals,
                    "{tag}: probe totals diverge from the measured prefix"
                );
                assert_eq!(
                    p.total_flits,
                    p.links.iter().map(|l| l.flits).sum::<u64>(),
                    "{tag}: link sums broken at driver level"
                );
                if p.total_flits > 0 {
                    assert!(p.bottleneck().is_some(), "{tag}: no bottleneck attributed");
                }
                // Probes never contaminate the extrapolated aggregates:
                // the probe-off run of the same policy is bit-identical.
                let mut off = cfg.clone();
                off.probes = false;
                let q = run_layer(&off, streaming, collection, &layer);
                assert!(q.probes.is_none(), "{tag}: probe-off run produced a report");
                assert_eq!(q.net, r.net, "{tag}: probes changed the simulation");
                assert_eq!(q.total_cycles, r.total_cycles, "{tag}: probes changed timing");
            }
        }
    }
}

#[test]
fn ru_probe_totals_match_the_closed_form_exactly() {
    // Repetitive unicast has contention-independent traffic: node x of a
    // row sends ppn 2-flit packets that cross M−x routers each (the
    // analytic flit-hop form), of which exactly one hop per flit is the
    // memory ejection — which never crosses a link. The probe layer must
    // land on the closed form minus those ejection hops, flit for flit.
    let cfg = {
        let mut c = SimConfig::table1_8x8(4);
        c.probes = true;
        c
    };
    let ppn = 4u32;
    let m = cfg.mesh_cols as u64;
    let mut net = Network::new(&cfg, Collection::RepetitiveUnicast);
    let y = 3u16;
    for x in 0..cfg.mesh_cols {
        net.post_result(0, Coord::new(x as u16, y), ppn);
    }
    assert!(net.run_until_idle(1_000_000), "RU row failed to drain");
    let hops = analytic::row_collection_flit_hops(&cfg, Collection::RepetitiveUnicast, ppn);
    assert_eq!(net.stats.flit_hops, hops, "simulated hops diverge from Eq. form");
    // m·ppn packets of unicast_packet_flits flits eject exactly once each.
    let ejection_hops = m * ppn as u64 * cfg.unicast_packet_flits as u64;
    let p = net.probe_report().unwrap();
    assert_eq!(p.total_flits, hops - ejection_hops);
    assert_eq!(net.stats.link_traversals, hops - ejection_hops);
    // Each payload rides one packet across (M−1−x) links: Σ = ppn·M(M−1)/2.
    assert_eq!(p.total_payloads, ppn as u64 * m * (m - 1) / 2);
    // Only the posted row's east links carry traffic, monotone eastward:
    // link x→x+1 carries the (x+1)·ppn packets of nodes 0..=x.
    for l in &p.links {
        if l.flits == 0 {
            continue;
        }
        assert_eq!(l.port, Port::East, "{}: RU traffic off the east path", l.label());
        assert_eq!(l.from.y, y, "{}: RU traffic left its row", l.label());
        let expect = (l.from.x as u64 + 1) * ppn as u64 * cfg.unicast_packet_flits as u64;
        assert_eq!(l.flits, expect, "{}: unexpected flit count", l.label());
        assert_eq!(l.payloads, (l.from.x as u64 + 1) * ppn as u64, "{}", l.label());
    }
}

/// Build the capacity-limited gather hotspot: η == ppn makes every node
/// of the posted row initiate its own (full) gather packet — boarding is
/// impossible, so the packet census is timing-independent and the east-
/// most link `(M−2,y)→E` is *strictly* hottest in every δ regime.
fn gather_hotspot(topology: TopologyKind) -> (SimConfig, ProbeReport<'static>, Network) {
    let mut cfg = SimConfig::table1_8x8(4);
    cfg.topology = topology;
    // Two-flit packets: the capacity closed form
    // η = (Lg−1)·⌊flit_bits/payload_bits⌋ (`SimConfig::gather_capacity`)
    // then yields exactly one body flit's worth of payload slots.
    cfg.gather_packet_flits = 2;
    cfg.probes = true;
    cfg.validate().unwrap();
    // Post ppn = η per node — derived, not hardcoded, so the census
    // below survives a flit/payload-width reconfiguration (or fails
    // loudly at the premise check instead of deep in a link assert).
    let ppn = cfg.gather_capacity();
    assert_eq!(
        ppn.div_ceil(cfg.gather_capacity()),
        1,
        "η == ppn premise: each node must fill exactly one packet"
    );
    let mut net = Network::new(&cfg, Collection::Gather);
    let y = 2u16;
    for x in 0..cfg.mesh_cols {
        net.post_result(0, Coord::new(x as u16, y), ppn);
    }
    assert!(net.run_until_idle(1_000_000), "{topology:?} hotspot failed to drain");
    assert_eq!(net.payloads_delivered, cfg.mesh_cols as u64 * ppn as u64);
    let p = net.probe_report().unwrap().into_owned();
    (cfg, p, net)
}

#[test]
fn bottleneck_attribution_pins_the_hotspot_link_on_mesh_and_torus() {
    for topology in [TopologyKind::Mesh, TopologyKind::Torus] {
        let (cfg, p, net) = gather_hotspot(topology);
        let m = cfg.mesh_cols as u64;
        let lg = cfg.gather_packet_flits as u64;
        // Re-derive the census constants from the closed forms instead
        // of hardcoding them: the hotspot posts ppn = η per node, so the
        // row initiates ⌈M·ppn/η⌉ packets — exactly M under the η == ppn
        // premise (one full packet per node, boarding impossible).
        let ppn = cfg.gather_capacity();
        let packets = (m * ppn as u64).div_ceil(cfg.gather_capacity() as u64);
        assert_eq!(packets, m, "{topology:?}: η == ppn premise broken");
        assert_eq!(p.total_flits, net.stats.link_traversals, "{topology:?}");
        // Analytic census: packet i initiates at column i and crosses
        // M−i routers; the `packets·Lg` ejection hops never touch a link.
        let hops = analytic::row_collection_flit_hops(&cfg, Collection::Gather, ppn);
        assert_eq!(net.stats.flit_hops, hops, "{topology:?}: hop census moved");
        assert_eq!(p.total_flits, hops - packets * lg, "{topology:?}: link census moved");
        // Attribution: strictly hottest is the east-most link of the row,
        // and the traffic on it is collection, not operand streaming.
        let b = p.bottleneck().unwrap_or_else(|| panic!("{topology:?}: no bottleneck"));
        assert_eq!(b.from, Coord::new(cfg.mesh_cols as u16 - 2, 2), "{topology:?}");
        assert_eq!(b.to, Coord::new(cfg.mesh_cols as u16 - 1, 2), "{topology:?}");
        assert_eq!(b.port, Port::East, "{topology:?}");
        assert_eq!(b.stage, BottleneckStage::Collection, "{topology:?}");
        assert_eq!(b.flits, (m - 1) * lg, "{topology:?}: hottest-link census moved");
        assert!(b.utilization > 0.0 && b.utilization <= 1.0, "{topology:?}");
        // Per-link: load is strictly increasing eastward along the row,
        // and nothing leaves it — on the torus that also proves gather
        // took no wrap link (the wrap's record would sit off-row or
        // westbound and fail here).
        for l in &p.links {
            if l.flits == 0 {
                continue;
            }
            assert_eq!(l.port, Port::East, "{topology:?} {}: off east path", l.label());
            assert_eq!(l.from.y, 2, "{topology:?} {}: left the row", l.label());
            assert_eq!(
                l.flits,
                (l.from.x as u64 + 1) * lg,
                "{topology:?} {}: unexpected census",
                l.label()
            );
            assert_eq!(l.payloads, (l.from.x as u64 + 1) * ppn as u64, "{topology:?}");
        }
    }
}

#[test]
fn torus_emits_wrap_links_in_the_report() {
    // The report's link list comes from the topology, not the mesh
    // assumption: an M×M torus has 4·M² directed links (every port of
    // every router is wired), a mesh only 4·M(M−1).
    let (_, mesh_report, _) = gather_hotspot(TopologyKind::Mesh);
    let (cfg, torus_report, _) = gather_hotspot(TopologyKind::Torus);
    let m = cfg.mesh_cols;
    assert_eq!(mesh_report.links.len(), 4 * m * (m - 1));
    assert_eq!(torus_report.links.len(), 4 * m * m);
}
