//! Property tests over the NoC simulator invariants (in-tree generator —
//! see `util::rng::check_cases`; the offline build has no proptest).
//!
//! Invariants:
//! * **conservation** — every posted payload is delivered exactly once,
//!   for arbitrary mesh sizes, PEs/router, δ, packet sizing and collection
//!   scheme — and, cycle by cycle, `posted == delivered + in flight`
//!   (no payload is silently dropped by VC/switch allocation, gather
//!   boarding or INA merging);
//! * **no deadlock/livelock** — all scenarios drain within a generous
//!   cycle bound (XY + credits + wormhole VC discipline);
//! * **gather/INA economy** — with ample δ, gather never injects more
//!   packets than repetitive unicast, and INA never moves more flit-hops
//!   than gather;
//! * **packet accounting** — injected = ejected (+ INA merges) after
//!   drain.
//!
//! Set `NOC_COLLECTION=ru|gather|ina` to pin every randomized case to one
//! collection scheme (the CI matrix runs the suite once per mode).

use noc_dnn::config::{Collection, DataflowKind, SimConfig};
use noc_dnn::noc::network::Network;
use noc_dnn::noc::Coord;
use noc_dnn::util::rng::{check_cases, Rng};

/// Random collection scheme, overridable by the `NOC_COLLECTION` env var
/// so CI can sweep the whole property suite per mode.
fn random_collection(rng: &mut Rng) -> Collection {
    match std::env::var("NOC_COLLECTION") {
        Ok(s) => Collection::parse(&s).expect("NOC_COLLECTION must be ru|gather|ina"),
        Err(_) => *rng.choose(&[
            Collection::Gather,
            Collection::RepetitiveUnicast,
            Collection::Ina,
        ]),
    }
}

/// Random-but-valid config.
fn random_cfg(rng: &mut Rng) -> SimConfig {
    let mesh = *rng.choose(&[4usize, 5, 8, 11, 16]);
    let n = *rng.choose(&[1usize, 2, 4, 8]);
    let mut cfg = SimConfig::table1(if mesh >= 8 { mesh } else { 8 }, n);
    // Shrink the mesh after table1 (which asserts n) to cover odd sizes.
    cfg.mesh_cols = mesh;
    cfg.mesh_rows = mesh;
    cfg.delta = rng.range(0, 3 * cfg.delta);
    cfg.gather_packet_flits = rng.range(2, 20) as usize;
    cfg.sim_rounds_cap = 4;
    cfg.validate().unwrap();
    cfg
}

#[test]
fn prop_payload_conservation_across_configs() {
    check_cases(0xC0FFEE, 60, |rng, case| {
        let cfg = random_cfg(rng);
        let collection = random_collection(rng);
        let rounds = rng.range(1, 3);
        let mut net = Network::new(&cfg, collection);
        let mut posted = 0u64;
        for r in 0..rounds {
            for y in 0..cfg.mesh_rows {
                for x in 0..cfg.mesh_cols {
                    if rng.chance(0.8) {
                        let p = rng.range(1, cfg.pes_per_router as u64) as u32;
                        net.post_result(r * 50, Coord::new(x as u16, y as u16), p);
                        posted += p as u64;
                    }
                }
            }
        }
        let bound = 2_000_000;
        let ok = net.run_until(|n| n.payloads_delivered >= posted, bound);
        assert!(
            ok && net.payloads_delivered == posted,
            "case {case}: delivered {}/{posted} (cfg mesh={} n={} δ={} Lg={} coll={:?})",
            net.payloads_delivered,
            cfg.mesh_cols,
            cfg.pes_per_router,
            cfg.delta,
            cfg.gather_packet_flits,
            collection,
        );
    });
}

#[test]
fn prop_flit_conservation_holds_every_cycle() {
    // The strong form of conservation: at *every* cycle boundary of a
    // randomized run — including one cut off mid-flight at an arbitrary
    // max_cycle — payloads injected == payloads ejected + payloads merged
    // into surviving packets (tracked on their heads) + payloads still
    // pending/staged/buffered. A flit silently dropped by VC or switch
    // allocation, boarding or INA merging breaks the equality at the
    // cycle it happens, not just at drain time.
    check_cases(0xF117C0DE, 40, |rng, case| {
        let cfg = random_cfg(rng);
        let collection = random_collection(rng);
        let rounds = rng.range(1, 3);
        let mut net = Network::new(&cfg, collection);
        let mut posted = 0u64;
        for r in 0..rounds {
            for y in 0..cfg.mesh_rows {
                for x in 0..cfg.mesh_cols {
                    if rng.chance(0.7) {
                        let p = rng.range(1, cfg.pes_per_router as u64) as u32;
                        net.post_result(r * 40, Coord::new(x as u16, y as u16), p);
                        posted += p as u64;
                    }
                }
            }
        }
        // Sample the invariant while traffic is in flight...
        let horizon = rng.range(10, 2_000);
        net.run_until(
            |n| {
                assert_eq!(
                    posted,
                    n.payloads_delivered + n.payloads_in_flight(),
                    "case {case}: payload leak at cycle {} ({:?})",
                    n.cycle,
                    collection,
                );
                false
            },
            horizon,
        );
        // ...and after the drain: everything delivered, nothing resident.
        let ok = net.run_until_idle(2_000_000);
        assert!(ok, "case {case}: network failed to drain ({collection:?})");
        assert_eq!(net.payloads_delivered, posted, "case {case}: delivery shortfall");
        assert_eq!(net.payloads_in_flight(), 0, "case {case}: residue after drain");
        assert_eq!(net.total_buffered_flits(), 0, "case {case}: flits stuck");
        assert_eq!(
            net.stats.packets_injected,
            net.stats.packets_ejected + net.stats.ina_merges,
            "case {case}: packet leak (absorbed packets must be the only shortfall)"
        );
    });
}

#[test]
fn prop_flit_conservation_holds_across_fast_forward_jumps() {
    // Bursts separated by multi-thousand-cycle idle gaps force
    // `run_until`'s quiescent fast-forward (and the calendar queue's
    // window hops) between bursts. The conservation invariant is checked
    // at every predicate call — including the iterations immediately
    // after a clock jump — so a post lost or duplicated by the event
    // schedule would fail at the cycle it happens.
    check_cases(0xFA57F0, 25, |rng, case| {
        let cfg = random_cfg(rng);
        let collection = random_collection(rng);
        let mut net = Network::new(&cfg, collection);
        let mut posted = 0u64;
        let mut at = 0u64;
        let mut last_burst = 0u64;
        for _ in 0..rng.range(2, 5) {
            at += rng.range(3_000, 40_000);
            last_burst = at;
            for y in 0..cfg.mesh_rows {
                if rng.chance(0.5) {
                    let x = rng.below(cfg.mesh_cols as u64) as u16;
                    let p = rng.range(1, cfg.pes_per_router as u64) as u32;
                    net.post_result(at, Coord::new(x, y as u16), p);
                    posted += p as u64;
                }
            }
        }
        if posted == 0 {
            // Degenerate draw: guarantee the clock has somewhere to jump.
            net.post_result(last_burst, Coord::new(0, 0), 1);
            posted = 1;
        }
        let done = net.run_until(
            |n| {
                assert_eq!(
                    posted,
                    n.payloads_delivered + n.payloads_in_flight(),
                    "case {case}: payload leak at cycle {} across a jump ({collection:?})",
                    n.cycle,
                );
                false
            },
            last_burst + 2_000_000,
        );
        assert!(!done, "always-false predicate cannot be satisfied");
        assert_eq!(
            net.payloads_delivered, posted,
            "case {case}: delivery shortfall after the jump-heavy schedule"
        );
        assert_eq!(net.payloads_in_flight(), 0, "case {case}: residue after drain");
        assert_eq!(net.total_buffered_flits(), 0, "case {case}: flits stuck");
        assert!(
            net.cycle >= last_burst,
            "case {case}: clock never reached the last burst (cycle {} < {last_burst})",
            net.cycle
        );
    });
}

#[test]
fn prop_packet_table_never_aliases_live_packets() {
    // The compact-flit kernel interns packet-constant fields in a slab
    // with free-list recycling (`noc::flit::PacketTable`). The bug class
    // a free list can introduce is aliasing: a slot recycled while a
    // stale flit still points at it. At every sampled cycle boundary —
    // under gather boarding storms and INA mid-flight absorbs alike —
    // every in-flight flit must reference a live slot with an in-range
    // seq (`audit_packet_table` panics otherwise) and the census must
    // reconcile: live == injected − ejected − merges.
    check_cases(0xA11A5, 30, |rng, case| {
        let cfg = random_cfg(rng);
        let collection = random_collection(rng);
        let mut net = Network::new(&cfg, collection);
        let mut posted = 0u64;
        for r in 0..rng.range(2, 4) {
            for y in 0..cfg.mesh_rows {
                for x in 0..cfg.mesh_cols {
                    if rng.chance(0.7) {
                        let p = rng.range(1, cfg.pes_per_router as u64) as u32;
                        net.post_result(r * rng.range(5, 60), Coord::new(x as u16, y as u16), p);
                        posted += p as u64;
                    }
                }
            }
        }
        let mut horizon = 0u64;
        for _ in 0..5 {
            horizon += rng.range(20, 800);
            net.run_until(|_| false, horizon);
            assert_eq!(
                net.packet_table().live(),
                net.stats.packets_injected - net.stats.packets_ejected - net.stats.ina_merges,
                "case {case}: packet-table census broken at cycle {} ({collection:?})",
                net.cycle
            );
            net.audit_packet_table();
        }
        assert!(net.run_until_idle(2_000_000), "case {case}: failed to drain");
        assert_eq!(net.payloads_delivered, posted, "case {case}: shortfall");
        assert_eq!(net.packet_table().live(), 0, "case {case}: slots leaked after drain");
        assert_eq!(
            net.audit_packet_table(),
            0,
            "case {case}: flits still in flight after drain"
        );
        // The slab never outgrows the high-water mark of simultaneously
        // live packets — capacity growth only happens with an empty free
        // list, so capacity == peak_live is exact, not a bound.
        assert_eq!(
            net.packet_table().capacity() as u64,
            net.packet_table().peak_live(),
            "case {case}: slab grew past the live high-water mark"
        );
    });
}

#[test]
fn prop_packet_table_occupancy_bounded_across_fast_forward_jumps() {
    // Idle gaps of thousands of cycles force the calendar fast-forward
    // between bursts; a retire lost or replayed across a jump (or a slot
    // double-released at the band barrier) corrupts the census right
    // after the jump — and each burst re-interns pids the previous burst
    // retired, so the walk also proves recycled slots never collide with
    // flits still draining.
    check_cases(0x5AB0B5, 20, |rng, case| {
        let cfg = random_cfg(rng);
        let collection = random_collection(rng);
        let mut net = Network::new(&cfg, collection);
        let mut posted = 0u64;
        let mut at = 0u64;
        for _ in 0..rng.range(2, 5) {
            at += rng.range(3_000, 40_000);
            for y in 0..cfg.mesh_rows {
                if rng.chance(0.6) {
                    let x = rng.below(cfg.mesh_cols as u64) as u16;
                    let p = rng.range(1, cfg.pes_per_router as u64) as u32;
                    net.post_result(at, Coord::new(x, y as u16), p);
                    posted += p as u64;
                }
            }
            net.run_until(|_| false, at + rng.range(1, 2_000));
            assert_eq!(
                net.packet_table().live(),
                net.stats.packets_injected - net.stats.packets_ejected - net.stats.ina_merges,
                "case {case}: census broken across a jump at cycle {} ({collection:?})",
                net.cycle
            );
            assert_eq!(
                net.packet_table().capacity() as u64,
                net.packet_table().peak_live(),
                "case {case}: slab outgrew its live high-water mark across a jump"
            );
            net.audit_packet_table();
        }
        if posted == 0 {
            net.post_result(at, Coord::new(0, 0), 1);
            posted = 1;
        }
        assert!(net.run_until_idle(at + 2_000_000), "case {case}: failed to drain after jumps");
        assert_eq!(net.payloads_delivered, posted, "case {case}: shortfall after jumps");
        assert_eq!(net.packet_table().live(), 0, "case {case}: slots leaked after jumps");
    });
}

#[test]
fn ina_mid_flight_retires_recycle_slots_without_aliasing() {
    // Collection pinned to INA regardless of `NOC_COLLECTION`: the
    // switch-allocation merge path (`absorb_ina_packet`) retires whole
    // packets *mid-flight*, the heaviest workout for free-list recycling.
    // Widely separated full-grid bursts drain completely between rounds,
    // so later bursts must re-intern the slots earlier bursts freed —
    // the final capacity strictly undercutting the injection census is
    // the proof that recycling actually happened.
    let cfg = SimConfig::table1_8x8(8);
    let mut net = Network::new(&cfg, Collection::Ina);
    let mut posted = 0u64;
    for r in 0..4u64 {
        for y in 0..cfg.mesh_rows {
            for x in 0..cfg.mesh_cols {
                net.post_result(r * 5_000, Coord::new(x as u16, y as u16), 8);
                posted += 8;
            }
        }
    }
    let mut horizon = 0u64;
    loop {
        horizon += 50;
        let done = net.run_until(|n| n.payloads_delivered >= posted, horizon);
        net.audit_packet_table();
        assert_eq!(
            net.packet_table().live(),
            net.stats.packets_injected - net.stats.packets_ejected - net.stats.ina_merges,
            "census broken at cycle {}",
            net.cycle
        );
        if done {
            break;
        }
        assert!(horizon < 2_000_000, "INA storm stalled at cycle {}", net.cycle);
    }
    assert!(net.run_until_idle(2_000_000), "INA storm failed to drain");
    assert_eq!(net.payloads_delivered, posted);
    assert_eq!(net.packet_table().live(), 0, "slots leaked after the storm");
    assert!(
        (net.packet_table().capacity() as u64) < net.stats.packets_injected,
        "slab capacity {} never recycled across {} injected packets",
        net.packet_table().capacity(),
        net.stats.packets_injected
    );
}

#[test]
fn prop_probe_partition_reconciles_with_netstats() {
    // With probes on, the per-link observability counters are a strict
    // partition of the aggregates this suite already pins: link sums
    // equal `NetStats::link_traversals` bit-exactly both mid-flight and
    // after drain, and turning probes on changes no simulated outcome
    // (same delivery count at the same final cycle as the probe-off
    // twin). The deeper pyramid lives in `tests/probe_invariants.rs`.
    check_cases(0x9B0B35, 30, |rng, case| {
        let mut cfg = random_cfg(rng);
        let collection = random_collection(rng);
        let mut schedule: Vec<(u64, Coord, u32)> = Vec::new();
        for y in 0..cfg.mesh_rows {
            for x in 0..cfg.mesh_cols {
                if rng.chance(0.7) {
                    schedule.push((
                        rng.range(0, 100),
                        Coord::new(x as u16, y as u16),
                        rng.range(1, cfg.pes_per_router as u64) as u32,
                    ));
                }
            }
        }
        let run = |probes: bool, cfg: &mut SimConfig| {
            cfg.probes = probes;
            let mut net = Network::new(cfg, collection);
            for &(at, node, p) in &schedule {
                net.post_result(at, node, p);
            }
            let horizon = 500;
            net.run_until(|_| false, horizon);
            if let Some(p) = net.probe_report() {
                assert_eq!(
                    p.total_flits, net.stats.link_traversals,
                    "case {case}: probe partition broken mid-flight ({collection:?})"
                );
            }
            assert!(net.run_until_idle(2_000_000), "case {case}: failed to drain");
            if let Some(p) = net.probe_report() {
                assert_eq!(
                    p.total_flits, net.stats.link_traversals,
                    "case {case}: probe partition broken after drain ({collection:?})"
                );
            }
            (net.stats.clone(), net.payloads_delivered, net.cycle)
        };
        let on = run(true, &mut cfg);
        let off = run(false, &mut cfg);
        assert_eq!(on, off, "case {case}: probes changed the simulation ({collection:?})");
    });
}

#[test]
fn prop_network_drains_completely() {
    check_cases(0xBEEF, 40, |rng, case| {
        let cfg = random_cfg(rng);
        let mut net = Network::new(&cfg, Collection::Gather);
        for y in 0..cfg.mesh_rows {
            net.post_result(
                rng.range(0, 30),
                Coord::new(rng.below(cfg.mesh_cols as u64) as u16, y as u16),
                cfg.pes_per_router as u32,
            );
        }
        let ok = net.run_until_idle(2_000_000);
        assert!(ok, "case {case}: network failed to drain");
        assert_eq!(net.total_buffered_flits(), 0, "case {case}: flits stuck in buffers");
        assert_eq!(
            net.stats.packets_injected, net.stats.packets_ejected,
            "case {case}: packet leak"
        );
    });
}

#[test]
fn prop_gather_injects_no_more_packets_than_ru() {
    check_cases(0xABCD, 30, |rng, case| {
        let mesh = *rng.choose(&[8usize, 16]);
        let n = *rng.choose(&[1usize, 2, 4, 8]);
        let cfg = SimConfig::table1(mesh, n);
        let run = |coll: Collection| {
            let mut net = Network::new(&cfg, coll);
            let total = (cfg.mesh_cols * cfg.mesh_rows * cfg.pes_per_router) as u64;
            for y in 0..cfg.mesh_rows {
                for x in 0..cfg.mesh_cols {
                    net.post_result(0, Coord::new(x as u16, y as u16), n as u32);
                }
            }
            let ok = net.run_until(|nn| nn.payloads_delivered >= total, 1_000_000);
            assert!(ok, "case {case}: stalled");
            net.stats.clone()
        };
        let g = run(Collection::Gather);
        let ru = run(Collection::RepetitiveUnicast);
        assert!(
            g.packets_injected <= ru.packets_injected,
            "case {case}: gather {} packets vs RU {}",
            g.packets_injected,
            ru.packets_injected
        );
        // And strictly fewer flit-hops whenever more than one payload per
        // row exists and the gather consolidation can kick in.
        if n >= 4 {
            assert!(
                g.flit_hops < ru.flit_hops,
                "case {case}: gather hops {} !< RU hops {}",
                g.flit_hops,
                ru.flit_hops
            );
        }
    });
}

#[test]
fn prop_ina_moves_no_more_traffic_than_gather_or_ru() {
    // INA's whole point: same payloads delivered, strictly less
    // hop-weighted traffic than gather (small constant packets) which in
    // turn undercuts RU — under ample δ on Table-1 configurations.
    check_cases(0x16A, 30, |rng, case| {
        let mesh = *rng.choose(&[8usize, 16]);
        let n = *rng.choose(&[1usize, 2, 4, 8]);
        let cfg = SimConfig::table1(mesh, n);
        let run = |coll: Collection| {
            let mut net = Network::new(&cfg, coll);
            let total = (cfg.mesh_cols * cfg.mesh_rows * n) as u64;
            for y in 0..cfg.mesh_rows {
                for x in 0..cfg.mesh_cols {
                    net.post_result(0, Coord::new(x as u16, y as u16), n as u32);
                }
            }
            // Drain fully so hop counters include the trailing flits.
            assert!(net.run_until_idle(1_000_000), "case {case}: {coll:?} stalled");
            assert_eq!(net.payloads_delivered, total, "case {case}: {coll:?} shortfall");
            net.stats.clone()
        };
        let ina = run(Collection::Ina);
        let g = run(Collection::Gather);
        let ru = run(Collection::RepetitiveUnicast);
        assert!(
            ina.flit_hops <= g.flit_hops,
            "case {case} (m={mesh} n={n}): INA hops {} !<= gather {}",
            ina.flit_hops,
            g.flit_hops
        );
        assert!(
            ina.flit_hops < ru.flit_hops,
            "case {case} (m={mesh} n={n}): INA hops {} !< RU {}",
            ina.flit_hops,
            ru.flit_hops
        );
        assert!(
            ina.packets_injected <= ru.packets_injected,
            "case {case}: INA injected {} vs RU {}",
            ina.packets_injected,
            ru.packets_injected
        );
    });
}

#[test]
fn prop_gather_packets_bounded_by_row_population() {
    // However adversarial δ is, a row never emits more gather packets per
    // round than it has nodes.
    check_cases(0x5EED, 30, |rng, case| {
        let n = *rng.choose(&[1usize, 2, 4, 8]);
        let mut cfg = SimConfig::table1_8x8(n);
        cfg.delta = rng.range(0, 80);
        let mut net = Network::new(&cfg, Collection::Gather);
        for x in 0..cfg.mesh_cols {
            net.post_result(0, Coord::new(x as u16, 0), n as u32);
        }
        let total = (cfg.mesh_cols * n) as u64;
        let ok = net.run_until(|nn| nn.payloads_delivered >= total, 1_000_000);
        assert!(ok, "case {case}: stalled");
        assert!(
            net.stats.packets_injected <= cfg.mesh_cols as u64,
            "case {case}: {} packets from an {}-node row (δ={})",
            net.stats.packets_injected,
            cfg.mesh_cols,
            cfg.delta
        );
    });
}

#[test]
fn prop_config_json_roundtrip() {
    check_cases(0x1234, 50, |rng, case| {
        let mut cfg = random_cfg(rng);
        cfg.trace_driven = rng.chance(0.5);
        cfg.ru_pack_payloads = rng.chance(0.5);
        cfg.dataflow = if rng.chance(0.5) {
            DataflowKind::WeightStationary
        } else {
            DataflowKind::OutputStationary
        };
        cfg.ws_rf_words = rng.range(64, 4096) as u32;
        cfg.collection = *rng.choose(&[
            Collection::Gather,
            Collection::RepetitiveUnicast,
            Collection::Ina,
        ]);
        let s = cfg.to_json();
        let back = SimConfig::from_json(&s).unwrap();
        assert_eq!(cfg, back, "case {case}: JSON round-trip changed the config");
    });
}
