//! Integration: the full python-AOT → rust-PJRT numeric path.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).
//! Every artifact is loaded, compiled and executed with deterministic
//! random tensors; outputs are checked against the in-tree rust reference
//! convolution — closing the loop python-oracle ⇄ Pallas-kernel ⇄ HLO
//! artifact ⇄ PJRT execution ⇄ rust reference.

use noc_dnn::models::lite;
use noc_dnn::runtime::layer_exec::LayerExecutor;
use noc_dnn::runtime::reference;
use noc_dnn::runtime::{max_abs_diff, Tensor};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn quickstart_artifact_matches_reference_conv() {
    let Some(dir) = artifacts_dir() else { return };
    let layer = lite::quickstart_layer();
    let mut ex = LayerExecutor::new(dir).unwrap();
    let input = Tensor::random(vec![1, layer.c, layer.h_in, layer.h_in], 1);
    let weights = Tensor::random(vec![layer.q, layer.c, layer.r, layer.r], 2);
    let got = ex.forward(&layer, &input, &weights).unwrap();
    let want = reference::conv2d(&input, &weights, layer.stride, layer.pad);
    assert_eq!(got.shape, want.shape);
    let diff = max_abs_diff(&got.data, &want.data);
    assert!(diff < 1e-3, "PJRT vs reference diverged: {diff}");
}

#[test]
fn all_lite_artifacts_execute_and_match() {
    let Some(dir) = artifacts_dir() else { return };
    let mut ex = LayerExecutor::new(dir).unwrap();
    for (i, layer) in lite::alexnet_lite().iter().enumerate() {
        let input = Tensor::random(vec![1, layer.c, layer.h_in, layer.h_in], 100 + i as u64);
        let weights =
            Tensor::random(vec![layer.q, layer.c, layer.r, layer.r], 200 + i as u64);
        let got = ex.forward(layer, &input, &weights).unwrap();
        let want = reference::conv2d(&input, &weights, layer.stride, layer.pad);
        let diff = max_abs_diff(&got.data, &want.data);
        assert!(diff < 5e-3, "layer {}: diff {diff}", layer.name);
    }
}

#[test]
fn compile_once_execute_many() {
    let Some(dir) = artifacts_dir() else { return };
    let layer = lite::quickstart_layer();
    let mut ex = LayerExecutor::new(dir).unwrap();
    let weights = Tensor::random(vec![layer.q, layer.c, layer.r, layer.r], 7);
    let mut prev: Option<Tensor> = None;
    for seed in 0..4 {
        let input = Tensor::random(vec![1, layer.c, layer.h_in, layer.h_in], seed);
        let out = ex.forward(&layer, &input, &weights).unwrap();
        if let Some(p) = prev {
            assert_ne!(p.data, out.data, "distinct inputs must give distinct outputs");
        }
        prev = Some(out);
    }
}

#[test]
fn gather_payload_accounting_matches_layer_outputs() {
    // Every output activation of a layer is carried by exactly one gather
    // payload: the OS mapping's useful_outputs equals the tensor size.
    use noc_dnn::config::SimConfig;
    use noc_dnn::dataflow::os::OsMapping;
    let layer = lite::quickstart_layer();
    let cfg = SimConfig::table1_8x8(1);
    let mapping = OsMapping::new(&cfg, &layer);
    let outputs = (layer.q as u64) * (layer.h_out() as u64).pow(2);
    assert_eq!(mapping.useful_outputs(&layer), outputs);
    // The padded round capacity is at least the useful outputs.
    assert!(mapping.rounds * mapping.payloads_per_round(&cfg) >= outputs);
}
