//! Integration tests for the `ScenarioBuilder` → `Scenario` façade: the
//! typed-error contract (no public constructor or parse path panics on
//! invalid input), prelude ergonomics, and the acceptance matrix — AlexNet
//! conv3 end-to-end on `Torus2D` and `ConcentratedMesh` under all three
//! collection schemes.

use noc_dnn::prelude::*;

fn conv3() -> ConvLayer {
    alexnet::conv_layers()[2].clone()
}

#[test]
fn torus_and_cmesh_run_alexnet_conv3_under_every_collection() {
    for topology in [TopologyKind::Torus, TopologyKind::CMesh] {
        for collection in
            [Collection::Gather, Collection::RepetitiveUnicast, Collection::Ina]
        {
            let scenario = ScenarioBuilder::new()
                .mesh(8)
                .pes_per_router(2)
                .topology(topology)
                .collection(collection)
                .rounds_cap(2)
                .build()
                .unwrap_or_else(|e| panic!("{topology:?}/{collection:?}: {e}"));
            let report = scenario.simulate(&conv3());
            assert!(
                report.run.total_cycles >= report.run.simulated_cycles,
                "{topology:?}/{collection:?}"
            );
            assert!(
                report.run.measured_net.packets_ejected > 0,
                "{topology:?}/{collection:?}: nothing reached the memory"
            );
            assert!(report.power.total_j > 0.0, "{topology:?}/{collection:?}");
            // The fabric actually reached the simulation.
            assert_eq!(scenario.topology().kind(), topology);
            if topology == TopologyKind::CMesh {
                assert_eq!(scenario.config().mesh_cols, 4);
                assert_eq!(scenario.config().pes_per_router, 8);
            }
        }
    }
}

#[test]
fn torus_survives_mesh_streaming_and_weight_stationary() {
    // Mesh streaming posts operand multicasts at the west/north edge
    // injection ports — which on a torus also terminate wrap links; this
    // pins the injection/credit interaction (and WS exercises column-free
    // steady-state streams).
    for dataflow in [DataflowKind::OutputStationary, DataflowKind::WeightStationary] {
        let scenario = ScenarioBuilder::new()
            .mesh(8)
            .pes_per_router(2)
            .topology(TopologyKind::Torus)
            .streaming(Streaming::Mesh)
            .dataflow(dataflow)
            .rounds_cap(2)
            .build()
            .unwrap();
        let report = scenario.simulate(&conv3());
        assert!(report.run.measured_net.packets_ejected > 0, "{dataflow:?}");
        assert!(report.run.measured_net.stream_deliveries > 0, "{dataflow:?}");
    }
}

#[test]
fn torus_ru_moves_fewer_flit_hops_than_the_mesh() {
    let run = |topology| {
        ScenarioBuilder::new()
            .mesh(8)
            .pes_per_router(2)
            .topology(topology)
            .collection(Collection::RepetitiveUnicast)
            .rounds_cap(2)
            .build()
            .unwrap()
            .simulate(&conv3())
    };
    let mesh = run(TopologyKind::Mesh);
    let torus = run(TopologyKind::Torus);
    assert!(
        torus.run.measured_net.flit_hops < mesh.run.measured_net.flit_hops,
        "torus {} vs mesh {}",
        torus.run.measured_net.flit_hops,
        mesh.run.measured_net.flit_hops
    );
}

#[test]
fn scenario_executes_whole_models_with_plans() {
    let scenario = ScenarioBuilder::new()
        .mesh(8)
        .pes_per_router(2)
        .topology(TopologyKind::Torus)
        .rounds_cap(2)
        .build()
        .unwrap();
    let model = Network::new(
        "tiny",
        vec![
            ConvLayer { name: "t1", c: 4, h_in: 8, r: 3, stride: 1, pad: 1, q: 16 },
            ConvLayer { name: "t2", c: 16, h_in: 8, r: 1, stride: 2, pad: 0, q: 8 },
        ],
    );
    let plan = NetworkPlan::uniform(scenario.uniform_policy(), model.len());
    let run = scenario.execute(&model, &plan).unwrap();
    assert_eq!(run.layers.len(), 2);
    assert_eq!(
        run.total_cycles,
        run.layers.iter().map(|l| l.total_cycles).sum::<u64>()
    );
    // A mismatched plan is a typed error surfaced through the Result.
    let bad = NetworkPlan::uniform(scenario.uniform_policy(), 5);
    assert!(scenario.execute(&model, &bad).is_err());
}

#[test]
fn no_public_construction_or_parse_path_panics_on_invalid_input() {
    // Keyword parsers.
    assert!(matches!(
        Collection::parse("broadcast"),
        Err(ConfigError::UnknownKeyword { what: "collection", .. })
    ));
    assert!(matches!(
        Streaming::parse("quantum"),
        Err(ConfigError::UnknownKeyword { what: "streaming", .. })
    ));
    assert!(matches!(
        DataflowKind::parse("rs"),
        Err(ConfigError::UnknownKeyword { what: "dataflow", .. })
    ));
    assert!(matches!(
        TopologyKind::parse("ring"),
        Err(ConfigError::UnknownKeyword { what: "topology", .. })
    ));
    // Builder geometry.
    assert!(matches!(
        ScenarioBuilder::new().mesh(1).build(),
        Err(ConfigError::Invalid { .. })
    ));
    assert!(matches!(
        ScenarioBuilder::new().mesh(7).topology(TopologyKind::CMesh).build(),
        Err(ConfigError::Invalid { what: "mesh", .. })
    ));
    // Torus needs dateline VCs.
    assert!(matches!(
        ScenarioBuilder::new()
            .topology(TopologyKind::Torus)
            .configure(|c| c.vcs = 1)
            .build(),
        Err(ConfigError::Invalid { what: "vcs", .. })
    ));
    // Config JSON.
    assert!(matches!(
        SimConfig::from_json("{\"topology\": \"moebius\"}"),
        Err(ConfigError::UnknownKeyword { what: "topology", .. })
    ));
    assert!(matches!(
        SimConfig::from_json("]["),
        Err(ConfigError::Json { .. })
    ));
    // Plan JSON, end to end.
    assert!(matches!(
        NetworkPlan::from_json("{\"policies\": [{\"streaming\": \"teleport\"}]}"),
        Err(ConfigError::UnknownKeyword { what: "streaming", .. })
    ));
    assert!(matches!(
        NetworkPlan::from_json("{}"),
        Err(ConfigError::Json { what: "plan", .. })
    ));
    // Errors render with enough context to act on.
    let msg = ScenarioBuilder::new()
        .mesh(7)
        .topology(TopologyKind::CMesh)
        .build()
        .unwrap_err()
        .to_string();
    assert!(msg.contains("mesh") && msg.contains('7'), "unhelpful error: {msg}");
}

#[test]
fn prelude_covers_the_quickstart_surface() {
    // Everything the README/lib.rs quickstarts name resolves from the
    // prelude alone (this file imports nothing else); `pallas::prelude`
    // is the same module.
    let scenario: Scenario = ScenarioBuilder::new().mesh(8).build().unwrap();
    let _: &SimConfig = scenario.config();
    let report: RunReport = scenario.simulate(&conv3());
    assert!(report.run.total_cycles > 0);
    let model = Network::alexnet();
    let _plan: NetworkPlan = NetworkPlan::uniform(LayerPolicy::proposed(), model.len());
    use noc_dnn::pallas::prelude as p2;
    let again = p2::ScenarioBuilder::new().mesh(8).build().unwrap();
    assert_eq!(again.config(), scenario.config());
}
