//! Serving-subsystem invariants: determinism, conservation, drain.
//!
//! Top — **bit-identity**: a seeded serving run's request ledger,
//! percentiles and profile (including the measuring run's NetStats) are
//! bit-identical across executor `threads` 1/2/4 and `intra_workers`
//! 1/4. Serving itself is single-threaded; the only way parallelism
//! could leak in is through the measured profile, and the network
//! executor guarantees those runs are bit-identical — this suite pins
//! the composition.
//!
//! Middle — **conservation**: `offered == completed + rejected + queued
//! + in_flight` is audited by the event loop at every sample point and
//! the violation count must be zero, under overload, multi-tenant
//! priority and closed-loop traffic alike.
//!
//! Base — **drain**: a closed-loop population offered below service
//! capacity ends with zero queued and zero in-flight requests, and every
//! offered request completes.
//!
//! Honours the `NOC_COLLECTION` CI matrix pin for the profile run.

use noc_dnn::config::{Collection, SimConfig, Streaming};
use noc_dnn::coordinator::executor::NetworkExecutor;
use noc_dnn::models::{ConvLayer, Network};
use noc_dnn::plan::{LayerPolicy, NetworkPlan};
use noc_dnn::serving::{
    serve, sweep, ArrivalKind, LayerCost, SchedKind, ServiceProfile, ServingConfig,
};

fn env_collection() -> Collection {
    match std::env::var("NOC_COLLECTION") {
        Ok(s) => Collection::parse(&s).expect("NOC_COLLECTION must be ru|gather|ina"),
        Err(_) => Collection::Gather,
    }
}

fn tiny_model() -> Network {
    Network::new(
        "tiny",
        vec![
            ConvLayer { name: "t1", c: 4, h_in: 8, r: 3, stride: 1, pad: 1, q: 16 },
            ConvLayer { name: "t2", c: 16, h_in: 8, r: 1, stride: 2, pad: 0, q: 8 },
        ],
    )
}

/// Measure the tiny model's service profile at one executor parallelism
/// setting; returns the profile plus a NetStats fingerprint of the
/// measuring run (the bit-identity witness below the profile).
fn profile_at(threads: usize, intra_workers: usize) -> (ServiceProfile, String) {
    let mut cfg = SimConfig::table1_8x8(2);
    cfg.sim_rounds_cap = 2;
    cfg.threads = threads;
    cfg.intra_workers = intra_workers;
    cfg.collection = env_collection();
    cfg.probes = true;
    let model = tiny_model();
    let plan = NetworkPlan::uniform(
        LayerPolicy {
            streaming: Streaming::TwoWay,
            collection: cfg.collection,
            dataflow: cfg.dataflow,
        },
        model.len(),
    );
    let run = NetworkExecutor::new(cfg).run(&model, &plan).unwrap();
    let nets: Vec<String> = run
        .layers
        .iter()
        .map(|l| format!("{:?}", l.report.run.net))
        .collect();
    (ServiceProfile::from_run(&run), nets.join(" | "))
}

fn near_capacity_cfg(profile: &ServiceProfile) -> ServingConfig {
    ServingConfig {
        arrival: ArrivalKind::Poisson,
        rate_per_mcycle: profile.capacity_per_mcycle(2) * 0.9,
        batch: 2,
        tenants: 2,
        sched: SchedKind::Priority,
        queue_cap: 16,
        max_inflight: 2,
        seed: 7,
        ..ServingConfig::default()
    }
}

#[test]
fn seeded_serving_is_bit_identical_across_executor_parallelism() {
    let (base_profile, base_nets) = profile_at(1, 1);
    let base_report = serve(&base_profile, &near_capacity_cfg(&base_profile)).unwrap();
    assert!(base_report.completed > 0, "the pinned config must retire requests");
    assert_eq!(base_report.conservation_violations, 0);
    let base_json = base_report.to_json().to_pretty();

    for (threads, intra) in [(2, 1), (4, 1), (1, 4), (2, 4)] {
        let (profile, nets) = profile_at(threads, intra);
        assert_eq!(
            nets, base_nets,
            "NetStats diverged at threads={threads}, intra_workers={intra}"
        );
        assert_eq!(
            profile.layers, base_profile.layers,
            "per-layer costs diverged at threads={threads}, intra_workers={intra}"
        );
        let report = serve(&profile, &near_capacity_cfg(&profile)).unwrap();
        assert_eq!(
            report.ledger, base_report.ledger,
            "request ledger diverged at threads={threads}, intra_workers={intra}"
        );
        assert_eq!(
            (report.p50(), report.p99(), report.p999()),
            (base_report.p50(), base_report.p99(), base_report.p999()),
            "percentiles diverged at threads={threads}, intra_workers={intra}"
        );
        assert_eq!(
            report.to_json().to_pretty(),
            base_json,
            "full report diverged at threads={threads}, intra_workers={intra}"
        );
    }
}

#[test]
fn same_seed_same_ledger_different_seed_different_ledger() {
    let profile = synthetic_profile();
    let cfg = ServingConfig {
        arrival: ArrivalKind::Poisson,
        rate_per_mcycle: 600.0,
        batch: 2,
        tenants: 2,
        sched: SchedKind::Priority,
        queue_cap: 8,
        duration: 3_000_000,
        seed: 11,
        ..ServingConfig::default()
    };
    let a = serve(&profile, &cfg).unwrap();
    let b = serve(&profile, &cfg).unwrap();
    assert_eq!(a.ledger, b.ledger);
    assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());

    let reseeded = ServingConfig { seed: 12, ..cfg };
    let c = serve(&profile, &reseeded).unwrap();
    assert!(a.completed > 0 && c.completed > 0);
    assert_ne!(a.ledger, c.ledger, "a different seed must reshuffle arrivals");
}

/// 4 layers x 250 cycles/image: capacity is 1000 req/Mcycle at batch 1.
fn synthetic_profile() -> ServiceProfile {
    ServiceProfile::synthetic(
        "synthetic",
        (0..4)
            .map(|i| LayerCost {
                name: format!("l{i}"),
                setup_cycles: 0,
                per_image_cycles: 250,
                reload_cycles: 0,
            })
            .collect(),
    )
}

#[test]
fn conservation_holds_under_overload_and_priority_tenants() {
    let profile = synthetic_profile();
    let cfg = ServingConfig {
        arrival: ArrivalKind::Uniform,
        rate_per_mcycle: 5_000.0, // 5x capacity
        batch: 2,
        tenants: 3,
        sched: SchedKind::Priority,
        queue_cap: 6,
        max_inflight: 2,
        duration: 1_000_000,
        ..ServingConfig::default()
    };
    let r = serve(&profile, &cfg).unwrap();
    assert_eq!(r.conservation_violations, 0, "audited at every sample point");
    assert_eq!(r.offered, r.accepted + r.rejected);
    assert!(r.rejected > 0, "5x overload into a 6-deep queue must reject");
    assert_eq!(r.accepted, r.completed, "the run drains fully");
    assert_eq!(r.queued_at_end, 0);
    assert_eq!(r.inflight_at_end, 0);
    assert_eq!(r.ledger.len() as u64, r.completed);
    // The ledger never duplicates or invents a request id.
    let mut ids: Vec<u64> = r.ledger.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len() as u64, r.completed);
}

#[test]
fn closed_loop_below_capacity_drains_to_zero_queue() {
    let profile = synthetic_profile();
    let cfg = ServingConfig {
        arrival: ArrivalKind::ClosedLoop,
        clients: 3,
        think_cycles: 5_000, // issue every ~6k cycles vs 1k service
        batch: 1,
        queue_cap: 16,
        max_inflight: 2,
        duration: 500_000,
        ..ServingConfig::default()
    };
    let r = serve(&profile, &cfg).unwrap();
    assert!(r.offered >= 3, "each client issues at least once");
    assert_eq!(r.rejected, 0, "an under-capacity closed loop never overflows");
    assert_eq!(r.completed, r.offered, "every issued request completes");
    assert_eq!(r.queued_at_end, 0, "the queue drains to zero");
    assert_eq!(r.inflight_at_end, 0);
    assert_eq!(r.conservation_violations, 0);
    assert!(
        r.queue_depth_max <= 3,
        "never more waiting requests than clients (got {})",
        r.queue_depth_max
    );
}

#[test]
fn sweep_reports_a_knee_and_a_monotone_p99_blowup_past_it() {
    let profile = synthetic_profile();
    let base = ServingConfig {
        arrival: ArrivalKind::Poisson,
        batch: 1,
        queue_cap: 32,
        max_inflight: 1,
        duration: 2_000_000,
        ..ServingConfig::default()
    };
    let rates = [100.0, 400.0, 800.0, 1500.0, 3000.0];
    let sw = sweep(&profile, &base, &rates).unwrap();
    let knee = sw.knee.expect("a 10x-under-capacity rate is pre-knee");
    assert!(knee < rates.len() - 1, "3x overload cannot be pre-knee");
    // Past the knee the tail only gets worse (two deeply saturated
    // points both pin near the full-queue sojourn, so allow a sliver of
    // sampling slack rather than demand strict ordering there).
    let p99s: Vec<u64> = sw.points.iter().map(|p| p.report.p99()).collect();
    for w in p99s[knee..].windows(2) {
        assert!(
            w[1] as f64 >= w[0] as f64 * 0.9,
            "p99 must not improve past the knee: {p99s:?}"
        );
    }
    assert!(
        p99s[rates.len() - 1] > p99s[knee],
        "deep saturation must blow the tail up: {p99s:?}"
    );
    let last = &sw.points[rates.len() - 1].report;
    assert!(last.rejected > 0, "3x overload into a 32-deep queue must reject");
    // Throughput can never exceed the serial-fabric capacity.
    let cap = profile.capacity_per_mcycle(1);
    for p in &sw.points {
        assert!(
            p.report.throughput_per_mcycle <= cap * 1.05,
            "throughput {} above capacity {cap}",
            p.report.throughput_per_mcycle
        );
    }
}

#[test]
fn serve_report_json_has_the_contract_keys() {
    let profile = synthetic_profile();
    let cfg = ServingConfig {
        arrival: ArrivalKind::Poisson,
        rate_per_mcycle: 500.0,
        batch: 2,
        duration: 2_000_000,
        ..ServingConfig::default()
    };
    let j = serve(&profile, &cfg).unwrap().to_json();
    for key in [
        "model",
        "serving",
        "offered",
        "accepted",
        "rejected",
        "completed",
        "throughput_per_mcycle",
        "utilization",
        "latency",
        "queue_depth",
        "conservation_violations",
        "bottleneck",
        "degraded",
    ] {
        assert!(j.get(key).is_some(), "report JSON lost key {key}");
    }
    let lat = j.get("latency").unwrap();
    for key in ["p50", "p99", "p999", "mean", "max", "count"] {
        assert!(lat.get(key).is_some(), "latency JSON lost key {key}");
    }
    // Round-trips through the crate's JSON parser.
    let back = noc_dnn::util::json::parse(&j.to_pretty()).unwrap();
    assert_eq!(
        back.get("offered").unwrap().as_u64(),
        j.get("offered").unwrap().as_u64()
    );
}
