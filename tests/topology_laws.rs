//! Property tests for the [`Topology`] trait laws (see
//! `rust/src/noc/topology.rs`): route minimality on the mesh, torus
//! wraparound hop bounds, route/neighbor consistency, no self-loops, and
//! dateline VC-class monotonicity — plus kernel-level equivalence pinning
//! `Mesh2D` to the pre-topology hardwired geometry.

use noc_dnn::config::{Collection, SimConfig, TopologyKind};
use noc_dnn::noc::topology::{build, ConcentratedMesh, Mesh2D, Topology, Torus2D};
use noc_dnn::noc::{Coord, Network, PacketType, Port};
use noc_dnn::util::rng::{check_cases, Rng};

fn fabrics() -> Vec<Box<dyn Topology>> {
    vec![
        Box::new(Mesh2D::new(8, 8)),
        Box::new(Torus2D::new(8, 8)),
        Box::new(Torus2D::new(6, 4)),
        Box::new(ConcentratedMesh::new(4, 4, 8)),
    ]
}

fn random_node(rng: &mut Rng, t: &dyn Topology) -> Coord {
    let (cols, rows) = t.dims();
    Coord::new(rng.below(cols as u64) as u16, rng.below(rows as u64) as u16)
}

/// Walk `route` hop by hop via `neighbor` until `dst`; panics on a
/// missing link or non-convergence. Returns the hop count.
fn walk(t: &dyn Topology, src: Coord, dst: Coord) -> u64 {
    let (cols, rows) = t.dims();
    let mut here = src;
    let mut hops = 0u64;
    while here != dst {
        assert!(
            hops <= (cols + rows) as u64 + 2,
            "{:?}: route {src:?} -> {dst:?} did not converge (at {here:?})",
            t.kind()
        );
        let p = t.route(PacketType::Unicast, here, dst);
        assert_ne!(p, Port::Local, "route returned Local before arrival");
        here = t
            .neighbor(here, p)
            .unwrap_or_else(|| panic!("{:?}: routed into missing link {p:?} at {here:?}", t.kind()));
        hops += 1;
    }
    hops
}

#[test]
fn prop_mesh_routes_are_minimal() {
    check_cases(0x7071, 200, |rng, _| {
        let m = Mesh2D::new(8, 8);
        let (src, dst) = (random_node(rng, &m), random_node(rng, &m));
        assert_eq!(walk(&m, src, dst), src.manhattan(&dst));
    });
}

#[test]
fn prop_torus_hops_bounded_by_half_dims() {
    // Ring-minimal routing: at most ⌈dim/2⌉ hops per dimension.
    check_cases(0x7072, 300, |rng, _| {
        for t in [Torus2D::new(8, 8), Torus2D::new(6, 4), Torus2D::new(5, 3)] {
            let (cols, rows) = t.dims();
            let (src, dst) = (random_node(rng, &t), random_node(rng, &t));
            let bound = (cols as u64).div_ceil(2) + (rows as u64).div_ceil(2);
            let hops = walk(&t, src, dst);
            assert!(hops <= bound, "{src:?}->{dst:?} on {cols}x{rows}: {hops} > {bound}");
            // And never worse than the mesh's manhattan walk.
            assert!(hops <= src.manhattan(&dst));
        }
    });
}

#[test]
fn prop_route_neighbor_consistency_and_no_self_loops() {
    check_cases(0x7073, 200, |rng, _| {
        for t in fabrics() {
            let t = t.as_ref();
            let node = random_node(rng, t);
            for p in [Port::North, Port::South, Port::East, Port::West] {
                if let Some(n) = t.neighbor(node, p) {
                    assert_ne!(n, node, "{:?}: self-loop at {node:?} {p:?}", t.kind());
                }
            }
            // walk() itself asserts that every routed port has a link.
            let dst = random_node(rng, t);
            walk(t, node, dst);
        }
    });
}

#[test]
fn prop_memory_routes_reach_the_east_edge_in_result_hops() {
    // Unicast result packets: the route to the virtual memory node
    // (cols, y) must reach the east-edge column and eject there, in
    // exactly `result_hops` router traversals (ejecting router included).
    check_cases(0x7074, 200, |rng, _| {
        for t in fabrics() {
            let t = t.as_ref();
            let (cols, _) = t.dims();
            let node = random_node(rng, t);
            let mem = Coord::new(cols as u16, node.y);
            let mut here = node;
            let mut routers = 1u64; // the source router itself
            loop {
                let p = t.route(PacketType::Unicast, here, mem);
                if here.x as usize == cols - 1 && p == Port::East {
                    break; // ejection into the memory element
                }
                assert!(routers <= cols as u64 + 2, "{:?}: no ejection", t.kind());
                here = t.neighbor(here, p).expect("routed into missing link");
                routers += 1;
            }
            assert_eq!(here.y, node.y, "{:?}: result left its row", t.kind());
            assert_eq!(routers, t.result_hops(node), "{:?} from {node:?}", t.kind());
            assert!(t.result_hops(node) <= t.worst_result_hops());
        }
    });
}

#[test]
fn prop_dateline_classes_are_monotone_per_dimension() {
    // Along any torus unicast path: class is 0 until the wrap hop, 1 from
    // it on, and never returns to 0 within the dimension. Non-unicast
    // packets and the mesh are never class-restricted.
    check_cases(0x7075, 300, |rng, _| {
        let t = Torus2D::new(8, 8);
        let (src, dst) = (random_node(rng, &t), random_node(rng, &t));
        let mut here = src;
        let mut last_class_x: Option<usize> = None;
        let mut guard = 0;
        while here != dst {
            let p = t.route(PacketType::Unicast, here, dst);
            let class = t.vc_class(PacketType::Unicast, src, here, dst, p);
            assert!(matches!(class, Some(0) | Some(1)), "unicast hop without a class");
            if matches!(p, Port::East | Port::West) {
                if let (Some(prev), Some(now)) = (last_class_x, class) {
                    assert!(now >= prev, "class regressed {prev} -> {now} in X");
                }
                last_class_x = class;
            }
            assert_eq!(
                t.vc_class(PacketType::Gather, src, here, dst, p),
                None,
                "gather packets must stay unrestricted"
            );
            here = t.neighbor(here, p).unwrap();
            guard += 1;
            assert!(guard < 32);
        }
        let m = Mesh2D::new(8, 8);
        assert_eq!(m.vc_class(PacketType::Unicast, src, src, dst, Port::East), None);
    });
}

#[test]
fn gather_paths_pin_the_row_walk_on_every_fabric() {
    // gather_path is the descriptive twin of route()'s gather arm: the
    // hop-by-hop walk a gather packet actually takes (initiator to the
    // ejecting east-edge router) must equal the advertised path exactly.
    for t in fabrics() {
        let (cols, rows) = t.dims();
        for row in 0..rows {
            let path = t.gather_path(row);
            assert_eq!(path.len(), cols, "{:?}", t.kind());
            for (x, c) in path.iter().enumerate() {
                assert_eq!(*c, Coord::new(x as u16, row as u16), "{:?}", t.kind());
            }
            let mem = Coord::new(cols as u16, row as u16);
            let mut here = path[0];
            let mut walked = vec![here];
            loop {
                let p = t.route(PacketType::Gather, here, mem);
                if here.x as usize == cols - 1 {
                    assert_eq!(p, Port::East, "{:?}: no ejection at the edge", t.kind());
                    break;
                }
                here = t.neighbor(here, p).expect("gather walk hit a missing link");
                walked.push(here);
                assert!(walked.len() <= cols, "{:?}: gather walk diverged", t.kind());
            }
            assert_eq!(walked, path, "{:?}: route() disagrees with gather_path", t.kind());
        }
    }
}

#[test]
fn default_network_topology_is_the_frozen_mesh() {
    // The golden equivalence suite (tests/golden_kernel.rs) compares the
    // event kernel against the frozen mesh-only reference kernel on the
    // table-1 config — which therefore must keep building Mesh2D.
    let cfg = SimConfig::table1_8x8(2);
    assert_eq!(cfg.topology, TopologyKind::Mesh);
    let net = Network::new(&cfg, Collection::Gather);
    assert_eq!(net.topology().kind(), TopologyKind::Mesh);
    assert_eq!(net.topology().dims(), (8, 8));
}

#[test]
fn explicit_mesh_topology_is_bit_identical_to_the_default() {
    use std::sync::Arc;
    let cfg = Arc::new(SimConfig::table1_8x8(2));
    let drive = |net: &mut Network| {
        for r in 0..3u64 {
            for y in 0..8 {
                for x in 0..8 {
                    net.post_result(r * 40, Coord::new(x, y), 2);
                }
            }
        }
        assert!(net.run_until_idle(1_000_000), "drain stalled");
    };
    let mut by_key = Network::shared(cfg.clone(), Collection::Gather);
    let mut explicit = Network::with_topology(
        cfg.clone(),
        Arc::new(Mesh2D::new(8, 8)),
        Collection::Gather,
    );
    drive(&mut by_key);
    drive(&mut explicit);
    assert_eq!(by_key.stats, explicit.stats);
    assert_eq!(by_key.cycle, explicit.cycle);
    assert_eq!(by_key.payloads_delivered, explicit.payloads_delivered);
}

#[test]
fn torus_network_drains_unicast_results_with_fewer_hops() {
    // RU collection on the torus takes the westside wrap shortcut: the
    // same workload must complete with strictly fewer flit-hops than on
    // the mesh, conserving every payload, under the dateline VC rule.
    let mesh_cfg = SimConfig::table1_8x8(2);
    let mut torus_cfg = mesh_cfg.clone();
    torus_cfg.topology = TopologyKind::Torus;
    let run = |cfg: &SimConfig| {
        let mut net = Network::new(cfg, Collection::RepetitiveUnicast);
        let mut posted = 0u64;
        for r in 0..3u64 {
            for y in 0..8 {
                for x in 0..8 {
                    net.post_result(r * 60, Coord::new(x, y), 2);
                    posted += 2;
                }
            }
        }
        assert!(net.run_until_idle(1_000_000), "drain stalled on {:?}", cfg.topology);
        assert_eq!(net.payloads_delivered, posted, "{:?} lost payloads", cfg.topology);
        assert_eq!(net.payloads_in_flight(), 0);
        net.stats.flit_hops
    };
    let mesh_hops = run(&mesh_cfg);
    let torus_hops = run(&torus_cfg);
    assert!(
        torus_hops < mesh_hops,
        "torus RU hops {torus_hops} should undercut mesh {mesh_hops}"
    );
}

#[test]
fn torus_gather_collection_matches_the_mesh_exactly() {
    // Gather/INA packets are pinned to the eastward row walk on every
    // fabric; with no unicast traffic in flight a torus run must be
    // bit-identical to the mesh run.
    for collection in [Collection::Gather, Collection::Ina] {
        let mesh_cfg = SimConfig::table1_8x8(2);
        let mut torus_cfg = mesh_cfg.clone();
        torus_cfg.topology = TopologyKind::Torus;
        let run = |cfg: &SimConfig| {
            let mut net = Network::new(cfg, collection);
            for r in 0..3u64 {
                for y in 0..8 {
                    for x in 0..8 {
                        net.post_result(r * 60, Coord::new(x, y), 2);
                    }
                }
            }
            assert!(net.run_until_idle(1_000_000), "drain stalled");
            (net.stats.clone(), net.cycle, net.payloads_delivered)
        };
        assert_eq!(run(&mesh_cfg), run(&torus_cfg), "{collection:?}");
    }
}

#[test]
fn cmesh_runs_the_same_workload_on_half_the_radix() {
    let cfg = SimConfig::table1(4, 8); // 4x4 routers, 8 PEs each
    let mut cmesh_cfg = cfg.clone();
    cmesh_cfg.topology = TopologyKind::CMesh;
    let mut net = Network::new(&cmesh_cfg, Collection::Gather);
    let mut posted = 0u64;
    for y in 0..4 {
        for x in 0..4 {
            net.post_result(0, Coord::new(x, y), 8);
            posted += 8;
        }
    }
    assert!(net.run_until_idle(1_000_000), "cmesh drain stalled");
    assert_eq!(net.payloads_delivered, posted);
    assert_eq!(net.topology().concentration(), 8);
    assert_eq!(build(&cmesh_cfg).kind(), TopologyKind::CMesh);
}
