//! Offline shim for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides the subset of the `anyhow` 1.x API the workspace uses:
//! [`Error`], [`Result`], the [`anyhow!`], [`bail!`] and [`ensure!`]
//! macros, and the [`Context`] extension trait for `Result` and `Option`.
//! Semantics match the real crate closely enough that swapping the path
//! dependency for the crates.io version is a no-op for this codebase:
//! `Display` shows the outermost message, `Debug` shows the full cause
//! chain, and any `std::error::Error + Send + Sync + 'static` converts
//! into [`Error`] via `?`.

use std::fmt;

/// A dynamic error: an outermost message plus the chain of causes that
/// produced it (outermost first).
pub struct Error {
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, as real anyhow does.
            write!(f, "{}", self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// As in real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`: that is what makes this blanket conversion
// coherent alongside `impl<T> From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        let v: u32 = "not a number".parse()?;
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = fails().unwrap_err();
        assert!(err.to_string().contains("invalid digit"));
    }

    #[test]
    fn context_wraps_outermost() {
        let err = fails().context("reading the config").unwrap_err();
        assert_eq!(err.to_string(), "reading the config");
        assert!(format!("{err:?}").contains("invalid digit"));
        assert!(format!("{err:#}").contains("invalid digit"));
        assert_eq!(err.chain().count(), 2);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let err = none.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(err.to_string(), "missing thing");
    }

    #[test]
    fn macros_build_errors() {
        fn inner(x: u32) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(())
        }
        assert!(inner(2).is_ok());
        assert_eq!(inner(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(inner(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }
}
